"""Trace-driven SM timing simulator (paper §V-A methodology, Table I).

A single GTX480-like SM: 48 warps, single-issue scheduler, L1D/shared
memory via :mod:`repro.core.onchip`, and a post-L1 stage — 768KB 8-way
banked L2 + DRAM bandwidth queueing — modeled by
:mod:`repro.core.memory`. Memory events map to latencies; blocked warps
wake on completion; fully-blocked stretches are skipped event-driven so
long traces stay fast in pure Python.

The post-L1 :class:`~repro.core.memory.MemoryHierarchy` may be private
(single-SM, the default) or shared between SMs: ``GPUSimulator``
(:mod:`repro.core.gpu`) passes one instance to every SM and advances them
in interleaved time slices via the :meth:`SMSimulator.begin` /
:meth:`SMSimulator.advance` stepping API, so SMs contend on the L2 banks
and DRAM channels. :meth:`SMSimulator.run` wraps the same API for the
classic run-to-completion use.

This is deliberately a *relative*-fidelity model: it reproduces the paper's
scheduler ordering phenomena (cache thrashing under GTO, CCWS' TLP loss on
compute-intensive codes, CIAO-P's isolation wins on small working sets,
CIAO-T on large ones, CIAO-C on both) rather than absolute GPU IPC.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from benchmarks.seed_core.interference import DetectorConfig, InterferenceDetector
from benchmarks.seed_core.memory import MemoryHierarchy
from benchmarks.seed_core.onchip import LINE, OnChipConfig, OnChipMemory
from benchmarks.seed_core.policies import BasePolicy, make_policy


def _default_detector() -> DetectorConfig:
    # Epochs scaled to our trace lengths (~200K instructions vs the paper's
    # tens of millions). The paper's own sensitivity sweep (Fig. 11a) shows
    # <15% IPC change across 1K..50K-instruction epochs; benchmarks sweep
    # this again (bench_sensitivity).
    return DetectorConfig(high_epoch=1000, low_epoch=50)


@dataclasses.dataclass
class SimConfig:
    num_warps: int = 48
    lat_l1: int = 1
    lat_smem: int = 1
    lat_migrate: int = 12         # response-queue round trip (§IV-B)
    lat_l2: int = 120
    lat_dram: int = 320
    dram_gap: int = 8             # cycles/request of DRAM bandwidth/channel
    dram_channels: int = 1
    l2_banks: int = 8
    l2_bank_gap: int = 0          # 0 = unqueued L2 (seed single-SM timing)
    max_mlp: int = 4              # outstanding memory requests per warp
    # every 2nd memory op is a dependent use (load-to-use stall): the warp
    # blocks until that request returns. This is what actually interleaves
    # warps on a real SM (GTO only switches when the greedy warp stalls).
    dep_every: int = 2
    l2_bytes: int = 768 * 1024
    l2_ways: int = 8
    max_cycles: int = 20_000_000
    detector: DetectorConfig = dataclasses.field(default_factory=_default_detector)
    onchip: OnChipConfig = dataclasses.field(default_factory=OnChipConfig)

    def make_hierarchy(self) -> MemoryHierarchy:
        return MemoryHierarchy(
            l2_bytes=self.l2_bytes, l2_ways=self.l2_ways, lat_l2=self.lat_l2,
            lat_dram=self.lat_dram, dram_gap=self.dram_gap,
            l2_banks=self.l2_banks, l2_bank_gap=self.l2_bank_gap,
            dram_channels=self.dram_channels)


@dataclasses.dataclass
class SimResult:
    policy: str
    cycles: int
    instructions: int
    ipc: float
    l1_hit_rate: float
    vta_hits: int
    mean_active_warps: float
    stats: Dict[str, int]
    timeline: List[Tuple[int, float, int]]  # (cycle, ipc_window, active)
    # interference pair events (evictor_wid, victim_wid, count), most
    # frequent first — the Fig. 4 skew data
    pairs: List[List[int]] = dataclasses.field(default_factory=list)


class SMSimulator:
    """One SM. Either ``run()`` to completion, or step it cooperatively:

        sm.begin()
        while not sm.finished:
            sm.advance(until_cycle)     # runs until local cycle >= until
        result = sm.result()
    """

    def __init__(self, workload, policy_name: str,
                 cfg: Optional[SimConfig] = None,
                 policy_kwargs: Optional[dict] = None,
                 mem_system: Optional[MemoryHierarchy] = None):
        """workload: object with .traces (list of (kinds u8, addrs i64)) and
        .smem_used_bytes (fraction of shared memory the app reserves).
        ``mem_system``: a shared post-L1 hierarchy; private when None."""
        self.cfg = cfg = cfg if cfg is not None else SimConfig()
        self._policy_name = policy_name
        self._policy_kwargs = policy_kwargs or {}
        self._smem_used_bytes = workload.smem_used_bytes
        self._mem_private = mem_system is None
        self.mem_sys = mem_system if mem_system is not None \
            else cfg.make_hierarchy()
        self.traces = workload.traces
        self.n = min(cfg.num_warps, len(self.traces))
        self._build_sm_state()
        self._begun = False

    def _build_sm_state(self) -> None:
        """Fresh detector + on-chip memory + policy (per-run state)."""
        cfg = self.cfg
        self.det = InterferenceDetector(cfg.detector)
        self.mem = OnChipMemory(cfg.onchip, self.det,
                                smem_used_bytes=self._smem_used_bytes)
        self.policy: BasePolicy = make_policy(
            self._policy_name, cfg.num_warps, self.det,
            **self._policy_kwargs)

    def _mem_latency(self, wid: int, addr: int) -> int:
        c = self.cfg
        isolated = self.policy.is_isolated(wid)
        bypass = self.policy.is_bypass(wid)
        event = self.mem.access(wid, addr, isolated=isolated, bypass=bypass)
        if event == "l1_hit":
            return c.lat_l1
        if event == "smem_hit":
            return c.lat_smem
        if event == "smem_migrate":
            return c.lat_migrate
        # goes to the (possibly shared) L2/DRAM stage
        lat, level = self.mem_sys.access(addr // LINE, self.cycle)
        if level == "dram":
            self.dram_reqs += 1
        return lat

    # -------------------------------------------------------- stepping API
    def begin(self) -> None:
        """Reset run state; must precede ``advance``. Re-running an
        instance gives identical results: detector, L1/smem, policy, and
        (when private) the L2/DRAM hierarchy are all rebuilt. A shared
        hierarchy is left alone — its owner (``GPUSimulator``) resets it
        once for all SMs."""
        if self._begun:
            self._build_sm_state()
        if self._mem_private:
            self.mem_sys.reset()
        n = self.n
        self.pc = [0] * n
        self.ready_at = [0] * n
        self.pending: List[List[int]] = [[] for _ in range(n)]
        self.mem_ord = [0] * n
        self.lens = [len(k) for k, _ in self.traces]
        self.done = [self.lens[w] == 0 for w in range(n)]
        self.remaining = sum(1 for w in range(n) if not self.done[w])
        self.instr = 0
        self.cycle = 0
        self.dram_reqs = 0
        self.active_samples: List[int] = []
        self.timeline: List[Tuple[int, float, int]] = []
        self._last_instr = 0
        self._last_cycle = 0
        self._window_mark = self.timeline_every
        self._epoch_counter = 0
        self._all_wids = list(range(n))
        self._kinds = [np.asarray(k) for k, _ in self.traces]
        self._addrs = [np.asarray(a) for _, a in self.traces]
        # next-memory-instruction index, for batching ALU runs
        self._next_mem = []
        for k_arr in self._kinds:
            nm = np.full(len(k_arr) + 1, len(k_arr), np.int64)
            prev = len(k_arr)
            for i in range(len(k_arr) - 1, -1, -1):
                if k_arr[i]:
                    prev = i
                nm[i] = prev
            self._next_mem.append(nm)
        self._begun = True

    timeline_every: int = 20_000

    @property
    def finished(self) -> bool:
        return self._begun and self.remaining == 0

    def advance(self, until: int) -> None:
        """Advance the SM until its local cycle reaches ``until`` (clamped
        there when every warp is blocked past the slice boundary, so a
        co-scheduled SM can interleave) or all warps finish."""
        c = self.cfg
        n = self.n
        until = min(until, c.max_cycles)
        pc, ready_at, pending = self.pc, self.ready_at, self.pending
        mem_ord, lens, done = self.mem_ord, self.lens, self.done
        kinds, addrs, next_mem = self._kinds, self._addrs, self._next_mem
        low_epoch = c.detector.low_epoch
        policy = self.policy
        det = self.det

        while self.remaining and self.cycle < until:
            # pick a warp: greedy (keep last), else oldest ready & allowed
            wid = policy.last_wid
            if wid is None or done[wid] or ready_at[wid] > self.cycle \
                    or not policy.allow(wid):
                wid = -1
                best = None
                for w in range(n):
                    if done[w] or not policy.allow(w):
                        continue
                    if ready_at[w] <= self.cycle:
                        wid = w
                        break
                    if best is None or ready_at[w] < best:
                        best = ready_at[w]
                if wid < 0:
                    if best is not None:
                        # event-driven skip, clamped to the slice boundary
                        self.cycle = min(best, until)
                    else:
                        # everything throttled: advance to let epochs fire
                        self.cycle += low_epoch
                        det.on_instruction(low_epoch)
                        policy.epoch_tick(self._all_wids, done,
                                          self._mem_util())
                    continue
                policy.last_wid = wid

            p = pc[wid]
            if kinds[wid][p]:
                addr = int(addrs[wid][p])
                before = det.vta_hit_events
                lat = self._mem_latency(wid, addr)
                if det.vta_hit_events > before:
                    policy.on_mem_event(wid, "vta_hit")
                mem_ord[wid] += 1
                done_t = self.cycle + lat
                if c.dep_every and mem_ord[wid] % c.dep_every == 0:
                    # dependent use: block until this request returns
                    ready_at[wid] = done_t
                else:
                    # hit-under-miss: keep issuing until max_mlp outstanding
                    pend = pending[wid]
                    pend.append(done_t)
                    if len(pend) > c.max_mlp:
                        pend[:] = [t for t in pend if t > self.cycle]
                    outstanding = [t for t in pend if t > self.cycle]
                    if len(outstanding) >= c.max_mlp:
                        ready_at[wid] = min(outstanding)
                    else:
                        ready_at[wid] = self.cycle + 1
                adv = 1
                self.cycle += 1
            else:
                # batch the ALU run up to the next memory instruction
                run_end = int(next_mem[wid][p])
                adv = run_end - p
                det.on_instruction(adv)
                self.cycle += adv
                ready_at[wid] = self.cycle
            pc[wid] += adv
            self.instr += adv
            if pc[wid] >= lens[wid]:
                done[wid] = True
                self.remaining -= 1
                policy.on_warp_done(wid)
                if policy.last_wid == wid:
                    policy.last_wid = None

            new_epoch = det.inst_total // low_epoch
            if new_epoch != self._epoch_counter:
                self._epoch_counter = new_epoch
                policy.epoch_tick(self._all_wids, done, self._mem_util())

            if self.instr >= self._window_mark:
                act = policy.num_allowed()
                self.active_samples.append(act)
                dc = max(self.cycle - self._last_cycle, 1)
                self.timeline.append(
                    (self.cycle, (self.instr - self._last_instr) / dc, act))
                self._last_instr = self.instr
                self._last_cycle = self.cycle
                self._window_mark += self.timeline_every

    def result(self) -> SimResult:
        ipc = self.instr / max(self.cycle, 1)
        pairs = sorted(([e, w, c] for (e, w), c
                        in self.det.pair_counts.items()),
                       key=lambda t: (-t[2], t[0], t[1]))
        return SimResult(
            policy=self.policy.name,
            cycles=self.cycle,
            instructions=self.instr,
            ipc=ipc,
            l1_hit_rate=self.mem.hit_rate(),
            vta_hits=self.det.vta_hit_events,
            mean_active_warps=(float(np.mean(self.active_samples))
                               if self.active_samples else float(self.n)),
            stats=dict(self.mem.stats, dram_reqs=self.dram_reqs),
            timeline=list(self.timeline),
            pairs=pairs,
        )

    # ------------------------------------------------------- classic entry
    def run(self, timeline_every: int = 20_000) -> SimResult:
        self.timeline_every = timeline_every
        self.begin()
        self.advance(self.cfg.max_cycles)
        return self.result()

    def _mem_util(self) -> float:
        return self.mem_sys.utilization(self.cycle)


def run_policy_sweep(workload, policies: Sequence[str],
                     cfg: Optional[SimConfig] = None,
                     best_swl_limits: Sequence[int] = (2, 4, 6, 8, 16, 32, 48),
                     ) -> Dict[str, SimResult]:
    """Run each policy; Best-SWL/statPCAL get their offline limit sweep
    (the paper profiles N_wrp per benchmark, Table II)."""
    cfg = cfg if cfg is not None else SimConfig()
    out: Dict[str, SimResult] = {}
    for p in policies:
        if p in ("best-swl", "statpcal"):
            best: Optional[SimResult] = None
            limits = ([workload.n_wrp] if getattr(workload, "n_wrp", 0)
                      else best_swl_limits)
            for lim in limits:
                r = SMSimulator(workload, p, cfg,
                                policy_kwargs={"limit": lim}).run()
                if best is None or r.ipc > best.ipc:
                    best = r
            out[p] = best
        else:
            out[p] = SMSimulator(workload, p, cfg).run()
    return out
