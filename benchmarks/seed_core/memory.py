"""Shared memory hierarchy below the SM: banked L2 + multi-channel DRAM.

The paper's GPU (Table I) is a 15-SM GTX480-class chip where all SMs share
a 768KB 8-way L2 and the DRAM channels. This module models that shared
stage behind a small interface so one :class:`MemoryHierarchy` instance can
be private to a single :class:`~repro.core.simulator.SMSimulator` (the
original single-SM setup) or shared by every SM of a
:class:`~repro.core.gpu.GPUSimulator`, where the per-bank and per-channel
queues make cross-SM contention visible: an LWS kernel streaming from one
SM delays the L2 fills of every other SM.

Timing model (relative fidelity, like the SM core model):

* **L2TagArray** — plain set-associative LRU tag store; hit/miss only.
* **BankedL2** — address-interleaved banks, each a serial port that accepts
  one request per ``bank_gap`` cycles; requests queue behind ``free_at``.
* **DRAMModel** — line-interleaved channels with ``gap`` cycles/request of
  bandwidth each (the seed model's single ``dram_free`` queue generalized).
* **MemoryHierarchy** — L2 lookup + queueing, then DRAM on a miss. ``now``
  is the requesting SM's local cycle; SMs advance in short interleaved time
  slices (see ``gpu.py``) so their clocks agree closely enough for the
  shared queues to be meaningful.

Defaults (``l2_bank_gap=0``, ``dram_channels=1``) reproduce the seed
single-SM timing exactly.
"""
from __future__ import annotations

from typing import Dict, Tuple

from benchmarks.seed_core.onchip import LINE


class L2TagArray:
    """Set-associative LRU tag store (hit/miss bookkeeping only)."""

    def __init__(self, size: int, ways: int):
        self.sets = max(size // (LINE * ways), 1)
        self.ways = ways
        self.tags = [[-1] * ways for _ in range(self.sets)]
        self.lru = [list(range(ways)) for _ in range(self.sets)]
        self.hits = 0
        self.misses = 0

    def access(self, line_addr: int) -> bool:
        s = line_addr % self.sets
        row = self.tags[s]
        for w in range(self.ways):
            if row[w] == line_addr:
                self.lru[s].remove(w)
                self.lru[s].append(w)
                self.hits += 1
                return True
        victim = self.lru[s][0]
        row[victim] = line_addr
        self.lru[s].remove(victim)
        self.lru[s].append(victim)
        self.misses += 1
        return False


class BankedL2:
    """Address-interleaved L2 banks, each a serial port with a queue."""

    def __init__(self, size: int, ways: int, banks: int = 8,
                 bank_gap: int = 0):
        self.tags = L2TagArray(size, ways)
        self.banks = max(banks, 1)
        self.bank_gap = bank_gap
        self.free_at = [0] * self.banks

    @property
    def hits(self) -> int:
        return self.tags.hits

    @property
    def misses(self) -> int:
        return self.tags.misses

    def access(self, line_addr: int, now: int) -> Tuple[bool, int]:
        """Returns (hit, queue_delay). The bank is busy for ``bank_gap``
        cycles after accepting a request; later requests queue."""
        hit = self.tags.access(line_addr)
        if not self.bank_gap:
            return hit, 0
        b = line_addr % self.banks
        start = max(now, self.free_at[b])
        self.free_at[b] = start + self.bank_gap
        return hit, start - now


class DRAMModel:
    """Per-channel bandwidth queueing: ``gap`` cycles per request."""

    def __init__(self, channels: int = 1, gap: int = 8):
        self.channels = max(channels, 1)
        self.gap = gap
        self.free_at = [0] * self.channels
        self.requests = 0

    def access(self, line_addr: int, now: int) -> int:
        """Returns the queueing delay before the request occupies its
        channel; the channel stays busy for ``gap`` cycles after that."""
        ch = (line_addr >> 2) % self.channels   # 512B channel interleave
        start = max(now, self.free_at[ch])
        self.free_at[ch] = start + self.gap
        self.requests += 1
        return start - now

    def utilization(self, now: int) -> float:
        if now <= 0:
            return 0.0
        return min(1.0, self.requests * self.gap / (self.channels * now))


class MemoryHierarchy:
    """L2 + DRAM stage shared by one or many SMs.

    ``access`` returns the full latency of a request that missed in the
    SM's on-chip stage (L1D / shared memory), including queueing at the L2
    bank and, on an L2 miss, at the DRAM channel.
    """

    def __init__(self, *, l2_bytes: int, l2_ways: int, lat_l2: int,
                 lat_dram: int, dram_gap: int, l2_banks: int = 8,
                 l2_bank_gap: int = 0, dram_channels: int = 1):
        self.lat_l2 = lat_l2
        self.lat_dram = lat_dram
        self._l2_params = (l2_bytes, l2_ways, l2_banks, l2_bank_gap)
        self._dram_params = (dram_channels, dram_gap)
        self.reset()

    def reset(self) -> None:
        """Fresh tags, queues, and counters (run boundaries)."""
        self.l2 = BankedL2(*self._l2_params)
        self.dram = DRAMModel(*self._dram_params)

    def access(self, line_addr: int, now: int) -> Tuple[int, str]:
        """One post-L1 request at SM-local cycle ``now``.
        Returns (latency, level) with level in {'l2', 'dram'}."""
        hit, queue = self.l2.access(line_addr, now)
        if hit:
            return self.lat_l2 + queue, "l2"
        dram_queue = self.dram.access(line_addr, now + queue)
        return self.lat_dram + queue + dram_queue, "dram"

    def utilization(self, now: int) -> float:
        """DRAM bandwidth utilization seen at cycle ``now`` (drives the
        statPCAL bypass decision)."""
        return self.dram.utilization(now)

    def stats(self) -> Dict[str, int]:
        return {"l2_hits": self.l2.hits, "l2_misses": self.l2.misses,
                "dram_reqs": self.dram.requests}
