"""Cache-interference detection (paper §III-A, §IV-A, Fig. 6).

Faithful implementation of:

* **Interference list** — 64 entries indexed by interfered WID, each holding
  a 6-bit interfering WID + 2-bit saturating counter. The counter tracks the
  *most recently and frequently* interfering warp: same-warp events increment
  (saturating at 3), different-warp events decrement; the stored WID is
  replaced only when the counter underflows at 0 (Fig. 4c).

* **Pair list** — 64 entries x two 6-bit fields: field 0 records which
  interfered warp triggered the *redirection* (isolation) of this warp,
  field 1 which triggered its *stall*. -1 = empty. Used by Algorithm 1 to
  undo actions in reverse order.

* **IRS** (Eq. 1): ``IRS_i = F_vta_hits(i) / (N_exec_inst / N_active_warps)``
  evaluated on two epochs — the high-cutoff epoch (5000 instructions, decide
  isolate/stall) and the low-cutoff epoch (100 instructions, decide
  reactivate/un-redirect). Cutoffs 0.01 / 0.005 (§IV-A; sensitivity §V-E).

The same detector instance is shared by the on-chip memory model (CIAO-P)
and the warp scheduler (CIAO-T) — paper §III-C notes L1D and shared-memory
interference do not mix, so one VTA suffices.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

from benchmarks.seed_core.vta import VictimTagArray

NO_WARP = -1


@dataclasses.dataclass
class DetectorConfig:
    num_warps: int = 48
    list_entries: int = 64           # §V-F: 64-entry interference/pair lists
    vta_sets: int = 48
    vta_tags_per_set: int = 8
    high_cutoff: float = 0.01
    low_cutoff: float = 0.005
    high_epoch: int = 5000           # instructions
    low_epoch: int = 100
    sat_max: int = 3                 # 2-bit saturating counter
    # Counter aging (refinement, ablatable): every N high epochs the
    # cumulative VTA-hit counters and the IRS instruction counter are
    # halved (hardware: shift right). Preserves Eq. 1 ratios but bounds the
    # history horizon so reactivation (low-cutoff test) tracks phase
    # changes instead of the whole-kernel average. 0 disables.
    aging_high_epochs: int = 10


class InterferenceDetector:
    def __init__(self, cfg: DetectorConfig = DetectorConfig()):
        self.cfg = cfg
        self.vta = VictimTagArray(cfg.vta_sets, cfg.vta_tags_per_set)
        n = cfg.list_entries
        self.interfering_wid: List[int] = [NO_WARP] * n
        self.sat_counter: List[int] = [0] * n
        self.pair_list: List[List[int]] = [[NO_WARP, NO_WARP] for _ in range(n)]
        self.inst_total = 0          # Inst-total counter (per SM)
        self.irs_inst = 0            # aged copy used as Eq. 1 denominator
        self.irs_hits = [0] * cfg.num_warps   # aged per-warp VTA-hit counters
        self.vta_hit_events = 0
        # (evictor, victim) -> event count; the Fig. 4 non-uniformity data.
        self.pair_counts: Dict[Tuple[int, int], int] = {}
        self._high_crossings = 0
        # windowed IRS state: snapshots taken at epoch crossings
        nw = cfg.num_warps
        self._low_idx = 0
        self._high_idx = 0
        self._low_base_hits = [0] * nw
        self._high_base_hits = [0] * nw
        self._low_base_inst = 0
        self._high_base_inst = 0
        self.irs_low_snap = [0.0] * nw
        self.irs_high_snap = [0.0] * nw

    # ------------------------------------------------------------- events
    def on_instruction(self, n: int = 1) -> None:
        self.inst_total += n
        self.irs_inst += n

    def on_eviction(self, owner_wid: int, line_addr: int,
                    evictor_wid: int) -> None:
        self.vta.insert(owner_wid, line_addr, evictor_wid)

    def on_miss(self, wid: int, line_addr: int) -> Optional[int]:
        """Probe VTA; on a VTA hit update the interference list (Fig. 4c)
        and return the interfering WID."""
        evictor = self.vta.probe(wid, line_addr)
        if evictor is None:
            return None
        self.vta_hit_events += 1
        self.irs_hits[wid % self.cfg.num_warps] += 1
        key = (evictor, wid)
        self.pair_counts[key] = self.pair_counts.get(key, 0) + 1
        i = wid % self.cfg.list_entries
        if self.interfering_wid[i] == evictor:
            self.sat_counter[i] = min(self.sat_counter[i] + 1, self.cfg.sat_max)
        elif self.interfering_wid[i] == NO_WARP:
            self.interfering_wid[i] = evictor
            self.sat_counter[i] = 0
        else:
            if self.sat_counter[i] == 0:
                self.interfering_wid[i] = evictor   # replace on underflow
            else:
                self.sat_counter[i] -= 1
        return evictor

    # ---------------------------------------------------------------- IRS
    def irs(self, wid: int, active_warps: int) -> float:
        """Eq. 1 over the aged cumulative counters."""
        if self.irs_inst == 0 or active_warps <= 0:
            return 0.0
        per_warp_inst = self.irs_inst / active_warps
        if per_warp_inst <= 0:
            return 0.0
        return self.irs_hits[wid % self.cfg.num_warps] / per_warp_inst

    def poll_epochs(self, active_warps: int) -> Tuple[bool, bool]:
        """Check for low/high epoch crossings (robust to batched instruction
        counting). At each crossing, snapshot the *windowed* IRS — Eq. 1
        evaluated over the epoch that just ended, so IRS tracks "the latest
        IRS_i" (§IV-A) and falls once an interferer is isolated/stalled."""
        cfg = self.cfg
        active_warps = max(active_warps, 1)
        crossed_low = crossed_high = False
        low_idx = self.inst_total // cfg.low_epoch
        if low_idx != self._low_idx:
            self._low_idx = low_idx
            window = max(self.inst_total - self._low_base_inst, 1)
            per_warp = window / active_warps
            for w in range(cfg.num_warps):
                h = self.vta.hit_count(w) - self._low_base_hits[w]
                self.irs_low_snap[w] = h / per_warp
                self._low_base_hits[w] = self.vta.hit_count(w)
            self._low_base_inst = self.inst_total
            crossed_low = True
        high_idx = self.inst_total // cfg.high_epoch
        if high_idx != self._high_idx:
            self._high_idx = high_idx
            window = max(self.inst_total - self._high_base_inst, 1)
            per_warp = window / active_warps
            for w in range(cfg.num_warps):
                h = self.vta.hit_count(w) - self._high_base_hits[w]
                self.irs_high_snap[w] = h / per_warp
                self._high_base_hits[w] = self.vta.hit_count(w)
            self._high_base_inst = self.inst_total
            crossed_high = True
            self._high_crossings += 1
            if cfg.aging_high_epochs and \
                    self._high_crossings % cfg.aging_high_epochs == 0:
                self.irs_inst //= 2
                self.irs_hits = [h // 2 for h in self.irs_hits]
        return crossed_low, crossed_high

    def irs_low(self, wid: int) -> float:
        return self.irs_low_snap[wid % self.cfg.num_warps]

    def irs_high(self, wid: int) -> float:
        return self.irs_high_snap[wid % self.cfg.num_warps]

    def most_interfering(self, wid: int) -> int:
        return self.interfering_wid[wid % self.cfg.list_entries]

    # ------------------------------------------------------------ pair list
    def record_isolation(self, interfering: int, interfered: int) -> None:
        self.pair_list[interfering % self.cfg.list_entries][0] = interfered

    def record_stall(self, interfering: int, interfered: int) -> None:
        self.pair_list[interfering % self.cfg.list_entries][1] = interfered

    def isolation_trigger(self, wid: int) -> int:
        return self.pair_list[wid % self.cfg.list_entries][0]

    def stall_trigger(self, wid: int) -> int:
        return self.pair_list[wid % self.cfg.list_entries][1]

    def clear_isolation(self, wid: int) -> None:
        self.pair_list[wid % self.cfg.list_entries][0] = NO_WARP

    def clear_stall(self, wid: int) -> None:
        self.pair_list[wid % self.cfg.list_entries][1] = NO_WARP

    # -------------------------------------------------------------- epochs
    def at_high_epoch(self) -> bool:
        return self.inst_total > 0 and self.inst_total % self.cfg.high_epoch == 0

    def at_low_epoch(self) -> bool:
        return self.inst_total > 0 and self.inst_total % self.cfg.low_epoch == 0
