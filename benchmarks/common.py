"""Shared benchmark utilities: CSV emission + timing."""
from __future__ import annotations

import time
from typing import Any, Callable, Iterable, List, Tuple

ROWS: List[Tuple[str, float, str]] = []


def emit(name: str, us_per_call: float, derived: Any) -> None:
    ROWS.append((name, us_per_call, str(derived)))
    print(f"{name},{us_per_call:.2f},{derived}")


def time_call(fn: Callable, *args, repeats: int = 3, **kw) -> float:
    """Median wall time in microseconds."""
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        # block on jax outputs if any
        try:
            import jax
            jax.block_until_ready(out)
        except Exception:
            pass
        times.append((time.perf_counter() - t0) * 1e6)
    times.sort()
    return times[len(times) // 2]


def header() -> None:
    print("name,us_per_call,derived")
