"""Beyond-paper: CIAO at the serving layer — tokens/work-unit and
preemptions under two pool-pressure levels."""
from __future__ import annotations

from benchmarks.common import emit
from repro.serving import PoolConfig, ServeConfig, ServeEngine, synth_requests

POLICIES = ("gto", "ccws", "statpcal", "ciao-p", "ciao-t", "ciao-c")


def main():
    for label, pool, heavy in (
        ("moderate", PoolConfig(main_pages=768, reserve_pages=224), 0.2),
        ("high", PoolConfig(main_pages=640, reserve_pages=192), 0.3),
    ):
        reqs = synth_requests(256, groups=10, prefix_pages=24,
                              decode_tokens=128, heavy_frac=heavy,
                              heavy_decode=1000)
        base = None
        for pol in POLICIES:
            cfg = ServeConfig(policy=pol, groups=10, pool=pool)
            st = ServeEngine(cfg).run(list(reqs))
            if pol == "gto":
                base = st.tokens_per_unit
            emit(f"serving/{label}/{pol}", 0.0,
                 f"tok_per_unit={st.tokens_per_unit:.3f}"
                 f";rel={st.tokens_per_unit / base:.3f}"
                 f";preempt={st.preemptions};refetch={st.refetched_pages}"
                 f";goodput={st.goodput:.1f}")


if __name__ == "__main__":
    main()
