"""Fig. 10 reproduction: CIAO-P vs CIAO-T vs CIAO-C on a small-working-set
(SYRK-like) and a large-working-set (KMN-like) benchmark."""
from __future__ import annotations

from benchmarks.common import emit
from repro.core import make_workload
from repro.core.simulator import run_policy_sweep


def main():
    for name in ("syrk", "kmn"):
        wl = make_workload(name, scale=0.5)
        res = run_policy_sweep(wl, ("gto", "ciao-p", "ciao-t", "ciao-c"))
        gto = res["gto"].ipc
        for p, r in res.items():
            emit(f"fig10/{name}/{p}", 0.0,
                 f"ipc={r.ipc / gto:.3f};hit={r.l1_hit_rate:.3f};"
                 f"act={r.mean_active_warps:.1f};"
                 f"smem_evics={r.stats.get('smem_evictions', 0)}")


if __name__ == "__main__":
    main()
