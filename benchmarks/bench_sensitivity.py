"""Fig. 11 reproduction: sensitivity of CIAO-C to the high-cutoff epoch
length and the high-cutoff threshold (low-cutoff fixed at half)."""
from __future__ import annotations

import dataclasses

from benchmarks.common import emit
from repro.core import make_workload
from repro.core.interference import DetectorConfig
from repro.core.simulator import SMSimulator, SimConfig


def main():
    wl = make_workload("syrk", scale=0.5)
    base = SMSimulator(wl, "gto").run().ipc
    # epoch sweep (paper: 1K..50K within 15%)
    for epoch in (250, 500, 1000, 2500, 5000):
        det = DetectorConfig(high_epoch=epoch, low_epoch=max(epoch // 20, 10))
        r = SMSimulator(wl, "ciao-c", SimConfig(detector=det)).run()
        emit(f"fig11a/high_epoch={epoch}", 0.0, f"{r.ipc / base:.3f}")
    # threshold sweep (paper: steady within 5%)
    for cutoff in (0.005, 0.01, 0.02, 0.04):
        det = DetectorConfig(high_epoch=1000, low_epoch=50,
                             high_cutoff=cutoff, low_cutoff=cutoff / 2)
        r = SMSimulator(wl, "ciao-c", SimConfig(detector=det)).run()
        emit(f"fig11b/high_cutoff={cutoff}", 0.0, f"{r.ipc / base:.3f}")


if __name__ == "__main__":
    main()
