"""Fig. 11 reproduction: sensitivity of CIAO-C to the high-cutoff epoch
length and the high-cutoff threshold (low-cutoff fixed at half).

Both sweeps are named ``SimConfig`` variants of one ``repro.core.runner``
grid; the GTO baseline is a second one-cell grid."""
from __future__ import annotations

from typing import Optional

from benchmarks.common import emit
from repro.core.interference import DetectorConfig
from repro.core.runner import ExperimentGrid, run_grid
from repro.core.simulator import SimConfig


def main(processes: Optional[int] = None,
         json_path: Optional[str] = None, engine: str = "auto"):
    variants = {}
    # epoch sweep (paper: 1K..50K within 15%)
    for epoch in (250, 500, 1000, 2500, 5000):
        det = DetectorConfig(high_epoch=epoch,
                             low_epoch=max(epoch // 20, 10))
        variants[f"fig11a/high_epoch={epoch}"] = SimConfig(detector=det)
    # threshold sweep (paper: steady within 5%)
    for cutoff in (0.005, 0.01, 0.02, 0.04):
        det = DetectorConfig(high_epoch=1000, low_epoch=50,
                             high_cutoff=cutoff, low_cutoff=cutoff / 2)
        variants[f"fig11b/high_cutoff={cutoff}"] = SimConfig(detector=det)

    base = run_grid(ExperimentGrid(name="fig11-base", workloads=("syrk",),
                                   policies=("gto",)),
                    processes=processes, engine=engine)[0].ipc
    records = run_grid(ExperimentGrid(name="fig11", workloads=("syrk",),
                                      policies=("ciao-c",),
                                      variants=variants),
                       processes=processes, json_path=json_path,
                       engine=engine)
    for r in records:
        emit(r.variant, 0.0, f"{r.ipc / base:.3f}")


if __name__ == "__main__":
    main()
