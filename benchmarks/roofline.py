"""Roofline analysis from the dry-run artifacts (deliverable g).

For every (arch x shape x mesh) JSON under artifacts/dryrun/:
    compute term    = HLO_FLOPs / peak_FLOPs            [s, per chip]
    memory term     = HLO_bytes / HBM_bw                [s, per chip]
    collective term = effective coll bytes / ICI links  [s, per chip]
(all three per device — the dry-run numbers are already post-SPMD
per-partition, with while-loop trip counts applied; see
launch/hlo_analysis.py). Dominant term -> the bottleneck. MODEL_FLOPS =
6·N·D (dense) / 6·N_active·D (MoE) for training (fwd+bwd), 2·N·D for
inference steps; the ratio MODEL_FLOPS/HLO_FLOPs exposes remat/replication
waste.

v5e constants: 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI; we credit
3 usable ICI links per chip on the 2D mesh (v5e has 4; one is discounted
for the DCI hop on the multi-pod mesh).
"""
from __future__ import annotations

import json
import pathlib
import sys
from typing import Dict, List, Optional

from repro.configs import ALL_SHAPES, get_config
from repro.configs.base import HBM_BW, ICI_BW, PEAK_BF16_FLOPS

ARTIFACTS = pathlib.Path(__file__).resolve().parents[1] / "artifacts" / "dryrun"
ICI_LINKS = 3


def model_flops(arch: str, shape_name: str) -> float:
    cfg = get_config(arch)
    shape = ALL_SHAPES[shape_name]
    n_active = cfg.active_param_count()
    if shape.mode == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.mode == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * shape.global_batch


def analyze_record(rec: Dict) -> Dict:
    chips = rec["num_devices"]
    flops_dev = rec["flops_per_device"]
    mem = rec.get("memory_analysis", {})
    # TPU-fusion HBM model: dot/conv/slice/collective boundary traffic
    # (loop-aware) + one read of the arguments and one write of the outputs
    # per step (weights/optimizer-state streams). The CPU-backend
    # every-op-boundary total is kept as a pessimistic upper bound.
    bytes_model = rec.get("bytes_hbm_model_per_device", 0.0) \
        + mem.get("argument_size_in_bytes", 0) \
        + mem.get("output_size_in_bytes", 0) \
        - mem.get("alias_size_in_bytes", 0)
    bytes_upper = rec["bytes_per_device"]
    coll_dev = rec["collectives"]["collective_total_effective"]
    t_compute = flops_dev / PEAK_BF16_FLOPS
    t_memory = bytes_model / HBM_BW
    t_memory_upper = bytes_upper / HBM_BW
    t_coll = coll_dev / (ICI_LINKS * ICI_BW)
    terms = {"compute": t_compute, "memory": t_memory,
             "collective": t_coll}
    dominant = max(terms, key=terms.get)
    mf = model_flops(rec["arch"], rec["shape"])
    hlo_total = flops_dev * chips
    bound = max(terms.values())
    useful_frac = (mf / chips) / PEAK_BF16_FLOPS / bound if bound else 0.0
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "tag": rec.get("tag", ""),
        "chips": chips,
        "t_compute_s": t_compute, "t_memory_s": t_memory,
        "t_memory_upper_s": t_memory_upper,
        "t_collective_s": t_coll, "dominant": dominant,
        "model_flops": mf, "hlo_flops_total": hlo_total,
        "useful_ratio": mf / hlo_total if hlo_total else 0.0,
        "roofline_fraction": useful_frac,
        "hbm_gb_per_dev": mem.get("total_hbm_bytes", 0) / 1e9,
        "compile_s": rec.get("compile_s", 0.0),
    }


def improvement_note(row: Dict) -> str:
    d = row["dominant"]
    if d == "compute":
        if row["useful_ratio"] < 0.4:
            return ("compute-bound with high waste: shard replicated "
                    "attention heads / skip masked tiles (Pallas splash) / "
                    "cheaper remat policy")
        return "compute-bound and efficient: scale batch or chips"
    if d == "memory":
        return ("HBM-bound: fuse elementwise chains, keep KV in bf16, "
                "widen arithmetic intensity (bigger per-chip batch)")
    return ("collective-bound: overlap all-gather with compute, int8 "
            "gradient compression on the pod axis, reorder FSDP gathers")


def load_rows(tag: str = "") -> List[Dict]:
    rows = []
    for p in sorted(ARTIFACTS.glob("*.json")):
        rec = json.loads(p.read_text())
        if (rec.get("tag") or "") != tag:
            continue
        rows.append(analyze_record(rec))
    return rows


def main(argv=None) -> None:
    tag = argv[1] if argv and len(argv) > 1 else ""
    rows = load_rows(tag)
    if not rows:
        print("no dry-run artifacts found; run repro.launch.dryrun first")
        return
    rows.sort(key=lambda r: (r["arch"], r["shape"], r["mesh"]))
    hdr = ("arch,shape,mesh,chips,t_compute_ms,t_memory_ms,t_coll_ms,"
           "dominant,useful_ratio,roofline_frac,hbm_gb_dev")
    print(hdr)
    out_lines = [hdr]
    for r in rows:
        line = (f"{r['arch']},{r['shape']},{r['mesh']},{r['chips']},"
                f"{1e3 * r['t_compute_s']:.2f},{1e3 * r['t_memory_s']:.2f},"
                f"{1e3 * r['t_collective_s']:.2f},{r['dominant']},"
                f"{r['useful_ratio']:.3f},{r['roofline_fraction']:.3f},"
                f"{r['hbm_gb_per_dev']:.1f}")
        print(line)
        out_lines.append(line)
    out = ARTIFACTS.parent / ("roofline.csv" if not tag
                              else f"roofline_{tag}.csv")
    out.write_text("\n".join(out_lines) + "\n")
    print(f"\nwrote {out}")


if __name__ == "__main__":
    main(sys.argv)
