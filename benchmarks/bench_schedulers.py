"""Fig. 8 reproduction: normalized IPC of 7 schedulers across the LWS /
SWS / CI benchmark classes + geometric means.

The policy × workload sweep runs through ``repro.core.runner`` — one
declarative grid, optional multiprocessing fan-out, optional JSON
persistence — instead of a hand-rolled loop."""
from __future__ import annotations

import time
from typing import Optional

from benchmarks.common import emit
from repro.core.runner import (ExperimentGrid, geomean, index_records,
                               run_grid)

POLICIES = ("gto", "ccws", "best-swl", "statpcal", "ciao-p", "ciao-t",
            "ciao-c")
BENCH_SET = ("kmn", "bicg", "mvt", "kmeans",            # LWS
             "syrk", "gesummv", "syr2k", "ii",          # SWS
             "backprop", "conv2d", "gaussian", "nw")    # CI


def main(scale: float = 0.5, processes: Optional[int] = None,
         json_path: Optional[str] = None, engine: str = "auto"):
    grid = ExperimentGrid(name="fig8", workloads=BENCH_SET,
                          policies=POLICIES, scale=scale)
    t0 = time.perf_counter()
    records = run_grid(grid, processes=processes, json_path=json_path,
                       engine=engine)
    us_per_cell = (time.perf_counter() - t0) * 1e6 / max(len(records), 1)

    by = index_records(records)
    per_class = {"LWS": {p: [] for p in POLICIES},
                 "SWS": {p: [] for p in POLICIES},
                 "CI": {p: [] for p in POLICIES}}
    allw = {p: [] for p in POLICIES}
    for name in BENCH_SET:
        gto = by[name, "gto", "base"].ipc
        for p in POLICIES:
            r = by[name, p, "base"]
            rel = r.ipc / max(gto, 1e-12)
            per_class[r.klass][p].append(rel)
            allw[p].append(rel)
            emit(f"fig8/{name}/{p}", us_per_cell, f"{rel:.3f}")
    for klass, data in per_class.items():
        for p in POLICIES:
            emit(f"fig8/geomean_{klass}/{p}", 0.0,
                 f"{geomean(data[p]):.3f}")
    for p in POLICIES:
        emit(f"fig8/geomean_all/{p}", 0.0, f"{geomean(allw[p]):.3f}")
    return {p: geomean(allw[p]) for p in POLICIES}


if __name__ == "__main__":
    main()
