"""Fig. 8 reproduction: normalized IPC of 7 schedulers across the LWS /
SWS / CI benchmark classes + geometric means."""
from __future__ import annotations

import math
import time

import numpy as np

from benchmarks.common import emit
from repro.core import WORKLOADS, make_workload
from repro.core.simulator import run_policy_sweep

POLICIES = ("gto", "ccws", "best-swl", "statpcal", "ciao-p", "ciao-t",
            "ciao-c")
BENCH_SET = ("kmn", "bicg", "mvt", "kmeans",            # LWS
             "syrk", "gesummv", "syr2k", "ii",          # SWS
             "backprop", "conv2d", "gaussian", "nw")    # CI


def main(scale: float = 0.5):
    per_class = {"LWS": {p: [] for p in POLICIES},
                 "SWS": {p: [] for p in POLICIES},
                 "CI": {p: [] for p in POLICIES}}
    allw = {p: [] for p in POLICIES}
    for name in BENCH_SET:
        wl = make_workload(name, scale=scale)
        t0 = time.perf_counter()
        res = run_policy_sweep(wl, POLICIES)
        dt = (time.perf_counter() - t0) * 1e6
        gto = res["gto"].ipc
        for p in POLICIES:
            rel = res[p].ipc / max(gto, 1e-12)
            per_class[wl.klass][p].append(rel)
            allw[p].append(rel)
            emit(f"fig8/{name}/{p}", dt / len(POLICIES), f"{rel:.3f}")
    for klass, data in per_class.items():
        for p in POLICIES:
            gm = math.exp(np.mean([math.log(max(x, 1e-9))
                                   for x in data[p]]))
            emit(f"fig8/geomean_{klass}/{p}", 0.0, f"{gm:.3f}")
    for p in POLICIES:
        gm = math.exp(np.mean([math.log(max(x, 1e-9)) for x in allw[p]]))
        emit(f"fig8/geomean_all/{p}", 0.0, f"{gm:.3f}")
    return {p: math.exp(np.mean([math.log(max(x, 1e-9)) for x in allw[p]]))
            for p in POLICIES}


if __name__ == "__main__":
    main()
